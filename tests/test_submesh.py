"""Chip-granular sub-mesh partitions (§3.4 second granularity).

Single-device half: partition-descriptor table keying (the collision
regression), KV-handoff charging, and the scheduler's combined-table
argmin — chip wins exactly when modeled handoff cost undercuts modeled
co-location contention. Multi-device half (@pytest.mark.multidevice, run
by the CI tier1-multidevice job under an 8-device forced host platform):
sub-mesh carving invariants and the acceptance property — prefill on
sub-mesh A, jax.device_put KV handoff, decode on sub-mesh B produces
token streams identical to the single-mesh fused engine.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import analytics as A
from repro.core.engine import BulletServer, ChipExecutable
from repro.core.estimator import (CycleObservation, EstimatorParams,
                                  HardwareSpec, PerfEstimator, predict_cycle)
from repro.core.metadata import (DecodeStatus, PrefillStatus, ResourceStatus,
                                 SystemState)
from repro.core.resource import ResourceManager
from repro.core.scheduler import SchedulerConfig, SLOScheduler
from repro.launch.submesh import carve_submeshes, find_split
from repro.serving.request import Request, SLO

KEY = jax.random.PRNGKey(0)

#: estimator regimes for the argmin tests: co-location contention priced
#: punitively + near-free interconnect (chip must win), and contention
#: priced away + starved interconnect (tile must win)
EST_CHEAP_HANDOFF = PerfEstimator(HardwareSpec(ici_bw=1e13),
                                  EstimatorParams(p_c=0.5, p_b=0.5))
EST_DEAR_HANDOFF = PerfEstimator(HardwareSpec(ici_bw=1e6),
                                 EstimatorParams(p_c=1.0, p_b=1.0))


def mixed_state(n_tokens=2048, n_d=8, ctx=512) -> SystemState:
    s = SystemState()
    s.prefill = PrefillStatus(active_rid=0, layers_done=0, total_layers=28,
                              n_tokens=n_tokens)
    s.decode = DecodeStatus(batch=list(range(n_d)), mean_context=ctx,
                            ctx_tokens=ctx * n_d)
    return s


def mk_scheduler(est, cfg=None, chip_splits=((1, 3), (2, 2), (3, 1))):
    cfg = cfg or get_config("qwen3-1.7b")
    rm = ResourceManager(est.hw, 2, chip_splits=list(chip_splits))
    sched = SLOScheduler(cfg, est, SLO(3.0, 150.0), SchedulerConfig())
    sched.split_candidates = [(p.prefill_units, p.decode_units)
                              for p in rm.tile_entries]
    sched.partition_table = rm.partitions
    return sched, rm


# ---------------------------------------------------------------------------
# partition-descriptor table keying (the nearest() collision regression)
# ---------------------------------------------------------------------------

def test_chip_and_tile_entries_with_same_units_stay_distinct():
    """Regression: a 2+2-chip split of a 4-chip machine projects to
    (16, 16) units — the same unit split as a tile table entry. The old
    units-keyed table collapsed them (nearest() quantized prefill_units
    and tie-broke by config_id); the descriptor key must keep both."""
    hw = HardwareSpec()                     # 4 chips x 8 units
    rm = ResourceManager(hw, 2, chip_splits=[(1, 3), (2, 2), (3, 1)])
    tile_status = ResourceStatus(16, 16)
    chip_status = ResourceStatus(16, 16, granularity="chip",
                                 prefill_chips=2, decode_chips=2)
    assert rm.on_table(tile_status) and rm.on_table(chip_status)
    tile_part = rm.nearest(tile_status)
    chip_part = rm.nearest(chip_status)
    assert tile_part.config_id != chip_part.config_id
    assert tile_part.granularity == "tile" and tile_part.prefill_chips == 0
    assert chip_part.granularity == "chip" and chip_part.prefill_chips == 2
    # and the unit projections really do coincide — the collision is real
    assert (tile_part.prefill_units, tile_part.decode_units) == \
        (chip_part.prefill_units, chip_part.decode_units) == (16, 16)


def test_chip_nearest_snaps_within_granularity():
    hw = HardwareSpec()
    rm = ResourceManager(hw, 2, chip_splits=[(1, 3), (2, 2), (3, 1)])
    # an off-table chip request snaps to the closest chip entry, never a
    # tile one
    got = rm.nearest(ResourceStatus(30, 2, granularity="chip",
                                    prefill_chips=4, decode_chips=0))
    assert got.granularity == "chip" and got.prefill_chips == 3
    # tile requests keep the quantize-then-snap behavior and never land
    # on a chip entry
    got = rm.nearest(ResourceStatus(17, 15))
    assert got.granularity == "tile"
    # switching onto a chip entry is still the instant table lookup
    part = rm.switch(ResourceStatus(8, 24, granularity="chip",
                                    prefill_chips=1, decode_chips=3))
    assert rm.current is part and part.granularity == "chip"


def test_descriptor_keys_unique_across_table():
    hw = HardwareSpec()
    rm = ResourceManager(hw, 2, chip_splits=[(1, 3), (2, 2), (3, 1)])
    keys = [p.key for p in rm.partitions]
    assert len(keys) == len(set(keys))
    assert len(rm.chip_entries) == 3
    assert rm.partitions == rm.tile_entries + rm.chip_entries


# ---------------------------------------------------------------------------
# KV-handoff charging
# ---------------------------------------------------------------------------

def test_kv_handoff_time_is_bytes_over_ici_bw():
    cfg = get_config("qwen3-1.7b")
    est = PerfEstimator(HardwareSpec(ici_bw=50e9))
    n = 4096
    want = A.kv_transfer_bytes(cfg, n) / 50e9
    assert est.kv_handoff_time(cfg, n) == pytest.approx(want)
    assert est.kv_handoff_time(cfg, 0) == 0.0
    assert est.kv_handoff_time(cfg, 2 * n) == pytest.approx(2 * want)


def test_chip_cycle_time_is_uncontended_max_plus_handoff():
    cfg = get_config("qwen3-1.7b")
    est = PerfEstimator()
    U = est.hw.total_units
    n_tok, batch, ctx = 4096, 16, 1024
    lg = len(cfg.pattern)
    t_p = est.prefill_layer_time(cfg, n_tok, 0, U // 2, colocated=False) * lg
    t_d = est.decode_iter_time(cfg, batch, ctx, U // 2, colocated=False)
    base = est.chip_cycle_time(cfg, n_tok, U // 2, U // 2, batch, ctx)
    assert base == pytest.approx(max(t_p, t_d))
    with_handoff = est.chip_cycle_time(cfg, n_tok, U // 2, U // 2, batch,
                                       ctx, handoff_tokens=n_tok)
    assert with_handoff == pytest.approx(
        max(t_p, t_d) + est.kv_handoff_time(cfg, n_tok))
    # one-sided cycles degrade to the single phase's time
    assert est.chip_cycle_time(cfg, n_tok, U // 2, U // 2, 0, 1) == \
        pytest.approx(t_p)


def test_predict_cycle_routes_chip_kind():
    cfg = get_config("qwen3-1.7b")
    est = PerfEstimator()
    obs = CycleObservation("chip", 1024, 16, 16, 4, 256,
                           handoff_tokens=1024)
    assert predict_cycle(est, cfg, obs) == pytest.approx(
        est.chip_cycle_time(cfg, 1024, 16, 16, 4, 256,
                            handoff_tokens=1024))
    # the handoff term is visible in the charge
    free = CycleObservation("chip", 1024, 16, 16, 4, 256)
    assert predict_cycle(est, cfg, obs) > predict_cycle(est, cfg, free)


# ---------------------------------------------------------------------------
# combined-table argmin (acceptance: chip wins iff handoff < contention)
# ---------------------------------------------------------------------------

def test_argmin_selects_chip_iff_handoff_beats_contention():
    state = mixed_state()
    sched_cheap, _ = mk_scheduler(EST_CHEAP_HANDOFF)
    gran, _ = sched_cheap.combined_argmin(state)
    assert gran == "chip"
    assert sched_cheap.preferred_granularity(state) == "chip"
    sched_dear, _ = mk_scheduler(EST_DEAR_HANDOFF)
    gran, _ = sched_dear.combined_argmin(state)
    assert gran == "tile"
    assert sched_dear.preferred_granularity(state) == "tile"
    # the argmin is literally the handoff-vs-contention comparison: the
    # winning chip cycle undercuts the best fused (contended) cycle in
    # one regime and not the other
    for sched, want_chip in ((sched_cheap, True), (sched_dear, False)):
        total = sched.est.hw.total_units
        _, chip_ms = sched._chip_split_search(state, float("inf"))
        tile_ms = min(sched._fused_cycle_ms(state, u, v)
                      for u, v in sched._fused_candidates(total))
        assert (chip_ms < tile_ms) == want_chip


def test_argmin_needs_both_phases_resident():
    sched, _ = mk_scheduler(EST_CHEAP_HANDOFF)
    no_decode = mixed_state(n_d=0)
    no_prefill = mixed_state(n_tokens=0)
    assert sched.combined_argmin(no_decode) is None
    assert sched.combined_argmin(no_prefill) is None
    assert sched.preferred_granularity(no_decode) == "tile"


def test_chip_schedule_decision_is_on_table_and_never_pauses():
    for est in (EST_CHEAP_HANDOFF, EST_DEAR_HANDOFF):
        sched, rm = mk_scheduler(est)
        d = sched.schedule(mixed_state(), 0.0, [], granularity="chip")
        assert d.resources.granularity == "chip"
        assert rm.on_table(d.resources)
        assert not d.pause_decode
        # single-phase cycles of a chip-pinned task stay on chip entries
        d = sched.schedule(mixed_state(n_d=0), 0.0, [], granularity="chip")
        assert d.resources.granularity == "chip"
        assert rm.on_table(d.resources)


def test_tile_schedule_unaffected_by_chip_table():
    """Without the granularity restriction the Algorithm 1/2 pipeline
    must keep proposing tile entries even when chip entries exist."""
    sched, rm = mk_scheduler(EST_CHEAP_HANDOFF)
    d = sched.schedule(mixed_state(), 0.0, [])
    assert d.resources.granularity == "tile"
    assert rm.on_table(d.resources)


# ---------------------------------------------------------------------------
# sub-mesh carving + real chip execution (multidevice)
# ---------------------------------------------------------------------------

def test_carve_single_device_yields_no_chip_table():
    assert carve_submeshes(jax.devices()[:1]) == []


@pytest.mark.multidevice
def test_carve_submeshes_disjoint_and_covering(chip_devices):
    splits = carve_submeshes(chip_devices)
    n = len(chip_devices)
    assert len(splits) == n - 1
    for s in splits:
        p = list(s.prefill_mesh.devices.flat)
        d = list(s.decode_mesh.devices.flat)
        assert len(p) == s.prefill_chips and len(d) == s.decode_chips
        assert s.prefill_chips + s.decode_chips == n
        assert not set(map(id, p)) & set(map(id, d))          # disjoint
        assert [*p, *d] == list(chip_devices)                 # covering
    assert find_split(splits, 1, n - 1) is splits[0]
    assert find_split(splits, n, 0) is None


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-1.7b").reduced(n_layers=2)
    from repro.models import init_params
    params = init_params(cfg, KEY, jnp.float32)
    return cfg, params


def mk_server(cfg, params, **kw):
    kw.setdefault("slo", SLO(3.0, 150.0))
    kw.setdefault("max_slots", 4)
    kw.setdefault("max_len", 48)
    kw.setdefault("max_prefill_batch", 1)
    kw.setdefault("sched", SchedulerConfig(max_decode_pause_cycles=0))
    return BulletServer(cfg, params, **kw)


def submit_batch(server, cfg, n=6, seed=0, out_len=8):
    rng = np.random.default_rng(seed)
    for rid in range(n):
        plen = int(rng.integers(4, 16))
        server.submit(Request(rid=rid, arrival=0.0, prompt_len=plen,
                              output_len=out_len),
                      rng.integers(0, cfg.vocab_size, plen))


@pytest.mark.multidevice
def test_chip_engine_matches_single_mesh_fused_engine(setup, chip_devices):
    """Acceptance: prefill on sub-mesh A, device_put KV handoff, decode on
    sub-mesh B — token streams identical to the single-mesh fused engine,
    with chip cycles and handoffs actually executed."""
    cfg, params = setup
    for seed in (0, 5):
        fused = mk_server(cfg, params)                     # single-mesh
        chip = mk_server(cfg, params, partition="chip",
                         devices=chip_devices[:2])
        assert chip._chip_enabled and chip.rm.chip_entries
        submit_batch(fused, cfg, seed=seed)
        submit_batch(chip, cfg, seed=seed)
        out_f = fused.run()
        out_c = chip.run()
        assert out_c == out_f, seed
        assert chip.stats.chip_cycles > 0
        assert chip.stats.handoffs > 0
        assert chip.stats.fused_cycles == 0                # pinned chip
        chip.pool.check_invariants()
        assert chip.pool.free_blocks == chip.pool.n_blocks


@pytest.mark.multidevice
def test_chip_engine_on_wider_submeshes(setup, chip_devices):
    """Same equivalence on asymmetric splits of the full device group
    (the 8-device CI mesh carves 7 splits; scheduling walks them)."""
    cfg, params = setup
    fused = mk_server(cfg, params)
    chip = mk_server(cfg, params, partition="chip", devices=chip_devices)
    assert len(chip.rm.chip_entries) == len(chip_devices) - 1
    submit_batch(fused, cfg, n=4, seed=3)
    submit_batch(chip, cfg, n=4, seed=3)
    assert chip.run() == fused.run()
    assert chip.stats.chip_cycles > 0 and chip.stats.handoffs > 0


@pytest.mark.multidevice
def test_auto_partition_argmin_drives_execution(setup, chip_devices):
    """partition="auto": the combined-table argmin decides per task.
    Under punitive contention + free interconnect every co-resident task
    runs chip-granular; in the opposite regime none does — and both
    regimes reproduce the single-mesh streams."""
    cfg, params = setup
    reference = mk_server(cfg, params)
    submit_batch(reference, cfg)
    out_ref = reference.run()
    for est, want_chip in ((EST_CHEAP_HANDOFF, True),
                           (EST_DEAR_HANDOFF, False)):
        server = mk_server(cfg, params, partition="auto", est=est,
                           devices=chip_devices[:2])
        submit_batch(server, cfg)
        out = server.run()
        assert out == out_ref
        if want_chip:
            assert server.stats.chip_cycles > 0
        else:
            assert server.stats.chip_cycles == 0
            assert server.stats.fused_cycles > 0


@pytest.mark.multidevice
def test_chip_executables_prebuilt_and_reused(setup, chip_devices):
    """Chip entries hold pre-built pjit pairs; switching is a table
    lookup that never rebuilds them (the libsmctrl-swap analogue at chip
    granularity)."""
    cfg, params = setup
    server = mk_server(cfg, params, partition="chip",
                       devices=chip_devices[:4])
    chip_execs = {cid: e for cid, e in server.rm._exec.items()
                  if isinstance(e, ChipExecutable)}
    assert len(chip_execs) == 3
    for part in server.rm.chip_entries:
        ex = server.rm.executable(part)
        assert isinstance(ex, ChipExecutable)
        assert ex.split.prefill_chips == part.prefill_chips
    submit_batch(server, cfg, n=4, seed=1)
    server.run()
    assert all(server.rm._exec[cid] is e for cid, e in chip_execs.items())
    assert server.stats.chip_cycles > 0
