"""The CI tier-1 matrix is defined by tests/shards.json (consumed by
.github/workflows/ci.yml via fromJSON). These tests make shard drift a red
tier-1 run instead of a silently-untested file: every tests/test_*.py must
be claimed by exactly one tier1 shard, every claimed path must exist, and
the workflow must actually read the shard file."""

import json
import pathlib
from collections import Counter

TESTS = pathlib.Path(__file__).resolve().parent
REPO = TESTS.parent
SHARDS = json.loads((TESTS / "shards.json").read_text())


def _claimed(shards):
    return [p for s in shards for p in s["paths"].split()]


def test_every_test_file_claimed_by_exactly_one_shard():
    claimed = Counter(_claimed(SHARDS["tier1"]))
    files = sorted(f"tests/{p.name}" for p in TESTS.glob("test_*.py"))
    dupes = sorted(p for p, n in claimed.items() if n > 1)
    assert not dupes, f"claimed by more than one shard: {dupes}"
    missing = sorted(set(files) - set(claimed))
    assert not missing, (
        f"test files not claimed by any tier1 shard (add them to "
        f"tests/shards.json): {missing}")
    stale = sorted(set(claimed) - set(files))
    assert not stale, f"shards claim nonexistent files: {stale}"


def test_shard_suites_named_uniquely():
    names = [s["suite"] for s in SHARDS["tier1"]]
    assert len(names) == len(set(names)), names


def test_multidevice_paths_exist():
    md = SHARDS["multidevice"]
    for p in (md["paths"] + " " + md["marked"]).split():
        assert (REPO / p).exists(), p


def test_workflow_consumes_shard_file():
    """The workflow must build its matrix from shards.json (fromJSON) —
    a hand-maintained path list in the YAML is the drift this file
    exists to kill."""
    wf = (REPO / ".github" / "workflows" / "ci.yml").read_text()
    assert "tests/shards.json" in wf
    assert "fromJSON(needs.shards.outputs.tier1)" in wf
    assert "tier1-multidevice" in wf
    assert "xla_force_host_platform_device_count=8" in wf
