"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates a REDUCED variant of the same family
(≤2-ish layers via one pattern period, d_model ≤ 512, ≤4 experts) and runs
one forward + one train step on CPU, asserting output shapes and no NaNs.
The FULL configs are exercised via the dry-run only (launch/dryrun.py).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import init_params, init_cache, forward, prefill, decode_step
from repro.training.trainer import make_train_step

B, S = 2, 16


def _frontend(cfg, key):
    if cfg.n_encoder_layers:
        return jax.random.normal(
            key, (B, cfg.encoder_seq_len, cfg.frontend_embed_dim))
    if cfg.frontend_embed_len:
        return jax.random.normal(
            key, (B, cfg.frontend_embed_len, cfg.frontend_embed_dim))
    return None


@pytest.fixture(scope="module", params=ASSIGNED_ARCHS)
def arch_setup(request):
    cfg = get_config(request.param).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return request.param, cfg, params


def test_reduced_config_limits(arch_setup):
    _, cfg, _ = arch_setup
    assert cfg.d_model <= 512
    assert cfg.n_layers <= len(cfg.pattern) + len(cfg.pattern_tail)
    assert cfg.n_experts <= 4


def test_forward_shapes_and_finite(arch_setup):
    name, cfg, params = arch_setup
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    fe = _frontend(cfg, jax.random.PRNGKey(2))
    logits, aux = forward(params, tokens, cfg, frontend=fe)
    s_out = S + (cfg.frontend_embed_len if fe is not None
                 and not cfg.n_encoder_layers else 0)
    assert logits.shape == (B, s_out, cfg.vocab_padded), name
    assert bool(jnp.all(jnp.isfinite(logits[..., :cfg.vocab_size]))), name
    assert bool(jnp.isfinite(aux)), name


def test_train_step_no_nans(arch_setup):
    name, cfg, params = arch_setup
    init_fn, step_fn = make_train_step(cfg, optimizer="adamw", remat=True,
                                       lr=1e-3, warmup=2)
    state = init_fn(params)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(4), (B, S), 0,
                                     cfg.vocab_size),
    }
    fe = _frontend(cfg, jax.random.PRNGKey(5))
    if fe is not None:
        batch["frontend"] = fe
    state, metrics = jax.jit(step_fn)(state, batch)
    assert np.isfinite(float(metrics["loss"])), name
    assert np.isfinite(float(metrics["grad_norm"])), name
    assert all(bool(jnp.all(jnp.isfinite(p)))
               for p in jax.tree.leaves(state.params)), name


def test_decode_matches_forward(arch_setup):
    """Prefill + decode must reproduce teacher-forcing logits."""
    name, cfg, params = arch_setup
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)  # no drops
    S0 = 10
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    fe = _frontend(cfg, jax.random.PRNGKey(2))
    full, _ = forward(params, tokens, cfg, frontend=fe)
    fe_len = (cfg.frontend_embed_len
              if fe is not None and not cfg.n_encoder_layers else 0)
    cache = init_cache(cfg, B, S + fe_len + 2, jnp.float32)
    lengths = jnp.array([S0 + fe_len] * B)
    lg, cache = prefill(params, tokens[:, :S0], lengths, cache, cfg,
                        frontend=fe)
    scale = max(float(jnp.abs(full).max()), 1.0)
    errs = [float(jnp.abs(lg - full[:, fe_len + S0 - 1]).max())]
    for t in range(S0, S):
        lg, cache = decode_step(params, cache, tokens[:, t:t + 1],
                                jnp.array([t + fe_len] * B), cfg)
        errs.append(float(jnp.abs(lg - full[:, fe_len + t]).max()))
    assert max(errs) < 2e-3 * scale, (name, errs)
