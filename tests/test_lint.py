"""AST lint guards over ``src/repro``.

The shared-mutable-default bug class has bitten this codebase before
(`SchedulerConfig()` as a dataclass default was one instance shared by
every server — see ``test_scheduler_config_is_per_server``). These
walkers keep it extinct:

- no function parameter may default to a mutable literal
  (list/dict/set/comprehension);
- no dataclass field may default to a bare ``SomeClass()`` call —
  ``field(default_factory=...)`` is the only sanctioned spelling, so
  every instance gets its own default object.
"""

import ast
import pathlib

SRC = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"

MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set,
                    ast.ListComp, ast.DictComp, ast.SetComp)


def _sources():
    files = sorted(SRC.rglob("*.py"))
    assert files, f"no sources under {SRC}"
    return files


def _call_name(call: ast.Call) -> str:
    fn = call.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return getattr(fn, "id", "")


def _is_dataclass(node: ast.ClassDef) -> bool:
    for dec in node.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = (target.attr if isinstance(target, ast.Attribute)
                else getattr(target, "id", ""))
        if name == "dataclass":
            return True
    return False


def test_no_mutable_literal_function_defaults():
    bad = []
    for path in _sources():
        tree = ast.parse(path.read_text(), filename=str(path))
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            defaults = list(node.args.defaults) + [
                d for d in node.args.kw_defaults if d is not None]
            for d in defaults:
                if isinstance(d, MUTABLE_LITERALS):
                    bad.append(f"{path.relative_to(SRC)}:{d.lineno} "
                               f"{node.name}()")
    assert not bad, ("mutable literal used as a function default "
                     f"(shared across calls): {bad}")


def test_dataclass_defaults_use_field_factory():
    bad = []
    for path in _sources():
        tree = ast.parse(path.read_text(), filename=str(path))
        for node in ast.walk(tree):
            if not (isinstance(node, ast.ClassDef) and _is_dataclass(node)):
                continue
            for stmt in node.body:
                if not (isinstance(stmt, ast.AnnAssign)
                        and stmt.value is not None):
                    continue
                v = stmt.value
                if isinstance(v, MUTABLE_LITERALS):
                    bad.append(f"{path.relative_to(SRC)}:{stmt.lineno} "
                               f"{node.name}")
                elif isinstance(v, ast.Call) and _call_name(v) != "field":
                    bad.append(f"{path.relative_to(SRC)}:{stmt.lineno} "
                               f"{node.name} = {_call_name(v)}()")
    assert not bad, ("dataclass default built by a call at class-body "
                     "time is one shared instance; use "
                     f"field(default_factory=...): {bad}")


def test_every_public_module_has_a_docstring():
    """Docstring coverage: every public module under src/repro (no
    leading underscore anywhere in its relative path) must open with a
    module docstring — the docs tree's section citations hang off them
    (see tests/test_docs.py)."""
    bad = []
    for path in _sources():
        rel = path.relative_to(SRC)
        if any(part.startswith("_") and part != "__init__.py"
               for part in rel.parts):
            continue
        tree = ast.parse(path.read_text(), filename=str(path))
        if not ast.get_docstring(tree):
            bad.append(str(rel))
    assert not bad, f"public modules without a module docstring: {bad}"


def test_guard_config_handoff_is_per_instance():
    """The concrete instance the audit caught: every GuardConfig must own
    its HandoffPolicy."""
    from repro.resilience.guard import GuardConfig
    a, b = GuardConfig(), GuardConfig()
    assert a.handoff is not b.handoff
