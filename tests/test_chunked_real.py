"""Chunked prefill at real-execution fidelity (the baseline's substrate):
N chunks through the live cache must equal the unchunked prefill exactly,
for every cache/state family, and decode must continue seamlessly."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import (decode_step, forward, init_cache, init_params,
                          prefill, prefill_chunk)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-2.7b",
                                  "recurrentgemma-2b", "mixtral-8x22b",
                                  "internvl2-76b"])
@pytest.mark.parametrize("chunk", [4, 8, 12])
def test_chunked_prefill_matches_full(arch, chunk):
    cfg = get_config(arch).reduced(frontend_embed_len=0,
                                   frontend_embed_dim=0)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    B, S = 2, 24
    assert S % chunk == 0
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    full, _ = forward(params, toks, cfg)
    cache = init_cache(cfg, B, S + 4, jnp.float32)
    for i in range(S // chunk):
        lg, cache = prefill_chunk(params, toks[:, i * chunk:(i + 1) * chunk],
                                  i * chunk, cache, cfg)
    scale = max(float(jnp.abs(full).max()), 1.0)
    assert float(jnp.abs(lg - full[:, S - 1]).max()) < 2e-3 * scale

    # decode continues identically from chunked vs unchunked caches
    cache_u = init_cache(cfg, B, S + 4, jnp.float32)
    _, cache_u = prefill(params, toks, jnp.array([S] * B), cache_u, cfg)
    nxt = jnp.full((B, 1), 1, jnp.int32)
    d1, _ = decode_step(params, cache, nxt, jnp.array([S] * B), cfg)
    d2, _ = decode_step(params, cache_u, nxt, jnp.array([S] * B), cfg)
    assert float(jnp.abs(d1 - d2).max()) < 2e-3 * scale


def test_chunked_rejects_encdec():
    cfg = get_config("seamless-m4t-large-v2").reduced()
    params = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.random.PRNGKey(0))
    with pytest.raises(AssertionError):
        prefill_chunk(params, jnp.zeros((1, 4), jnp.int32), 0,
                      init_cache(cfg, 1, 8, abstract=True), cfg)
