"""Block-paged device KV cache: engine equivalence vs the dense fallback,
preempt→resume and migrate round-trips, block-table invariants, and the
per-slot context charging the paged layout makes honest."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import analytics as A
from repro.core.engine import BulletServer
from repro.core.estimator import PerfEstimator
from repro.kvcache.paged import PagedKVPool
from repro.serving.request import Phase, Request, SLO


@pytest.fixture(scope="module")
def setup():
    # 2 pattern repeats -> 2 layer-group launches per prefill, so decode
    # iterations interleave with in-flight prefills (the path where stale
    # slot state must not reach a prefilling request's pages)
    cfg = get_config("qwen3-1.7b").reduced(n_layers=2)
    params = init_params_cached(cfg)
    return cfg, params


_params_cache = {}


def init_params_cached(cfg):
    if "p" not in _params_cache:
        from repro.models import init_params
        _params_cache["p"] = init_params(cfg, jax.random.PRNGKey(0),
                                         jnp.float32)
    return _params_cache["p"]


def mk_server(cfg, params, **kw):
    kw.setdefault("slo", SLO(3.0, 150.0))
    kw.setdefault("max_slots", 4)
    kw.setdefault("max_len", 48)
    return BulletServer(cfg, params, **kw)


def submit_batch(server, cfg, n=6, seed=0, out_len=5):
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(n):
        plen = int(rng.integers(4, 16))
        prompt = rng.integers(0, cfg.vocab_size, plen)
        r = Request(rid=rid, arrival=0.0, prompt_len=plen, output_len=out_len)
        server.submit(r, prompt)
        reqs.append(r)
    return reqs


# ---------------------------------------------------------------------------
# dense-path equivalence
# ---------------------------------------------------------------------------

def test_paged_engine_matches_dense_engine(setup):
    """Acceptance: the paged device cache is a pure layout change — token
    streams are identical to the dense fallback on the same requests.
    6 requests over 4 slots with 2-group prefills: slots get recycled and
    decode iterations run between the layer groups of later admissions."""
    cfg, params = setup
    outs = {}
    for seed in (0, 3, 7):
        dense = mk_server(cfg, params, paged=False)
        paged = mk_server(cfg, params)                # auto: paged for ATTN
        assert paged.paged and not dense.paged
        submit_batch(dense, cfg, seed=seed)
        submit_batch(paged, cfg, seed=seed)
        out_d = dense.run()
        out_p = paged.run()
        assert out_p == out_d, seed
        assert paged.stats.migrated == dense.stats.migrated == 6
        paged.pool.check_invariants()
        assert paged.pool.free_blocks == paged.pool.n_blocks
        outs[seed] = out_p
    assert len(outs) == 3


def test_paged_auto_fallback_for_non_attn(setup):
    """Architectures outside the paged layout keep the dense cache; asking
    for paged explicitly raises."""
    cfg = get_config("mamba2-2.7b").reduced()
    from repro.models import init_params
    params = init_params(cfg, jax.random.PRNGKey(1), jnp.float32)
    server = mk_server(cfg, params)
    assert not server.paged
    with pytest.raises(ValueError):
        mk_server(cfg, params, paged=True)


# ---------------------------------------------------------------------------
# block-table invariants across migrate / finish
# ---------------------------------------------------------------------------

def _tables_consistent(server):
    server._sync_tables()
    tbl = server._host_tables
    for slot, r in enumerate(server.slot_req):
        if r is None or r.phase != Phase.DECODE:
            # empty and mid-prefill slots must stay on the trash page so
            # decode-iteration writes can never touch real pages
            assert (tbl[slot] == server._trash_page).all(), slot
            continue
        pt = server.pool.table(r.rid)
        used = pt.blocks[:server.max_blocks]
        assert list(tbl[slot][:len(used)]) == used
        assert (tbl[slot][len(used):] == server._trash_page).all()


def test_migrate_roundtrip_block_tables(setup):
    """Prefill→decode migration is table-ownership only: mid-run the
    device tables always mirror the pool's page tables, and every block id
    addresses a real page (the trash page fills the rest)."""
    cfg, params = setup
    server = mk_server(cfg, params)
    reqs = submit_batch(server, cfg, n=5, seed=3)
    now, guard = 0.0, 0
    while not server.idle:
        server.step(now)
        now += 1e-3
        guard += 1
        assert guard < 10_000
        _tables_consistent(server)
        assert (server._host_tables <= server._trash_page).all()
        assert (server._host_tables >= 0).all()
    assert all(r.phase == Phase.FINISHED for r in reqs)
    server.pool.check_invariants()
    # everything freed: tables are all trash again
    _tables_consistent(server)
    assert (server._host_tables == server._trash_page).all()


def test_interleaved_prefill_pages_protected(setup):
    """Decode iterations that run between a later admission's layer groups
    write stale per-slot K/V (the slot's previous occupant's pos/tokens);
    those writes must land on the trash page, never inside the pages the
    new occupant's prefill has already scattered. Scenario: slot 0's first
    occupant finishes at position 9, then a 30-token prompt reuses slot 0
    while slot 1 keeps decoding — position 9 of the new prompt sits inside
    its attended range, so any poisoning shows up in the token stream."""
    cfg, params = setup
    rng = np.random.default_rng(2)
    prompts = {0: rng.integers(0, cfg.vocab_size, 6),
               1: rng.integers(0, cfg.vocab_size, 6),
               2: rng.integers(0, cfg.vocab_size, 30)}
    outs = {}
    for paged in (False, True):
        server = mk_server(cfg, params, max_slots=2, max_len=64,
                           max_prefill_batch=1, paged=paged)
        server.submit(Request(rid=0, arrival=0.0, prompt_len=6,
                              output_len=4), prompts[0])
        server.submit(Request(rid=1, arrival=0.0, prompt_len=6,
                              output_len=30), prompts[1])
        now = 0.0
        while len(server.finished) == 0:        # r0 finishes, slot frees
            server.step(now)
            now += 1e-3
        late = Request(rid=2, arrival=now, prompt_len=30, output_len=6)
        server.submit(late, prompts[2])
        interleaved = 0
        while late.phase != Phase.FINISHED:
            before = server.stats.decode_iterations
            server.step(now)
            if (server.ptask is not None and server.ptask.rep >= 1
                    and server.stats.decode_iterations > before):
                interleaved += 1
            now += 1e-3
        assert interleaved >= 1, "no decode ran between late layer groups"
        server.run()
        outs[paged] = dict(server.outputs)
    assert outs[True] == outs[False]


# ---------------------------------------------------------------------------
# preempt → resume round-trip
# ---------------------------------------------------------------------------

def _run_preemption_scenario(server, cfg):
    """Force a KV-pressure eviction mid-decode, then drain."""
    server.pool = PagedKVPool(48, block_size=16)      # 3 blocks: pressure
    rng = np.random.default_rng(1)
    young = Request(rid=0, arrival=1.0, prompt_len=8, output_len=12)
    young_prompt = rng.integers(0, cfg.vocab_size, 8)
    server.submit(young, young_prompt)
    now = 1.0
    while young.phase != Phase.DECODE:
        server.step(now)
        now += 1e-3
    for _ in range(3):
        server.step(now)
        now += 1e-3
    old = Request(rid=1, arrival=0.0, prompt_len=30, output_len=4)
    server.submit(old, rng.integers(0, cfg.vocab_size, 30))
    while old.phase == Phase.QUEUED:
        server.step(now)
        now += 1e-3
    assert server.stats.preempted == 1
    assert young.phase == Phase.QUEUED
    server.run()
    return young, old


def test_paged_preempt_resume_roundtrip(setup):
    """Eviction frees the victim's pages back to the pool (ownership move,
    no device copy); resume re-admits with the generated prefix intact and
    the final streams match the dense path bit for bit."""
    cfg, params = setup
    outs = {}
    for paged in (False, True):
        server = mk_server(cfg, params, max_slots=2, max_len=40,
                           max_prefill_batch=1, paged=paged)
        young, old = _run_preemption_scenario(server, cfg)
        assert young.phase == Phase.FINISHED
        assert old.phase == Phase.FINISHED
        assert len(server.outputs[0]) == young.output_len == 12
        assert len(server.outputs[1]) == old.output_len == 4
        server.pool.check_invariants()
        assert server.pool.free_blocks == server.pool.n_blocks
        if paged:
            _tables_consistent(server)
        outs[paged] = dict(server.outputs)
    assert outs[True] == outs[False]


# ---------------------------------------------------------------------------
# per-slot context charging (estimator honesty)
# ---------------------------------------------------------------------------

def test_decode_cost_scales_with_live_context():
    cfg = get_config("qwen3-1.7b")
    max_len, b = 2048, 8
    dense = A.decode_cost(cfg, b, max_len, contexts=[max_len] * b)
    live = A.decode_cost(cfg, b, 0, contexts=[max_len // 4] * b,
                         page_size=16)
    assert dense.kv_bytes / live.kv_bytes > 3.0
    # page round-up: 1 token still streams a whole page per slot
    one = A.decode_cost(cfg, b, 0, contexts=[1] * b, page_size=16)
    plain = A.decode_cost(cfg, b, 0, contexts=[1] * b)
    assert one.kv_bytes > plain.kv_bytes
    # contexts == batch×mean collapses to the legacy charge
    legacy = A.decode_cost(cfg, b, 512)
    exact = A.decode_cost(cfg, b, 0, contexts=[512] * b)
    assert legacy.kv_bytes == exact.kv_bytes


def test_estimator_charges_summed_contexts():
    est = PerfEstimator()
    cfg = get_config("qwen3-1.7b")
    skew = [64, 64, 64, 1920]          # mean 528
    t_mean = est.decode_iter_time(cfg, 4, 528, 16)
    t_exact = est.decode_iter_time(cfg, 4, 0, 16, contexts=skew)
    # same total tokens -> same linear KV charge (difference only from
    # truncation); the exact form must agree within rounding
    assert abs(t_mean - t_exact) / t_mean < 0.01


def test_last_decode_records_per_slot_contexts(setup):
    cfg, params = setup
    server = mk_server(cfg, params)
    submit_batch(server, cfg, n=3, seed=5, out_len=4)
    now = 0.0
    seen = False
    while not server.idle:
        server.step(now)
        now += 1e-3
        if server.last_decode is not None:
            w = server.last_decode
            assert w.batch == len(w.contexts) == len(w.streamed) > 0
            assert all(c >= 1 for c in w.contexts)
            # the kernel streams whole bucketed pages for all max_slots
            # rows (idle slots fetch the trash page), apportioned over
            # the slots that ran: at least each slot's live context, at
            # most the whole device pool sweep
            assert all(s >= c for s, c in zip(w.streamed, w.contexts))
            cap = (server.max_blocks * server.page_size
                   * server.max_slots // max(w.batch, 1))
            assert all(s <= cap for s in w.streamed)
            seen = True
    assert seen
