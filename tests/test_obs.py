"""Observability layer (docs/OBSERVABILITY.md): histogram/percentile
math, Prometheus rendering, disabled-path no-ops, Chrome trace-event
schema validity under VirtualClock, span invariants across a
preempt→resume round-trip, and snapshot↔EngineStats reconciliation."""

import json
import math
from dataclasses import fields as dataclass_fields

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import BulletServer
from repro.kvcache.paged import PagedKVPool
from repro.models import init_params
from repro.obs import NULL_OBS, Observability
from repro.obs.metrics import (MetricsRegistry, NULL_INSTRUMENT,
                               _NullInstrument)
from repro.serving.frontend import (OnlineFrontend, VirtualClock,
                                    estimator_cycle_cost)
from repro.serving.request import (Phase, Request, ServingMetrics, SLO,
                                   WORKLOAD_SLOS)
from repro.serving.workload import generate_trace


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-1.7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


@pytest.fixture(scope="module")
def replayed(setup):
    """One instrumented virtual-clock replay shared by the export tests:
    estimator-clocked so every cycle gets a recorded actual."""
    cfg, params = setup
    obs = Observability()
    server = BulletServer(cfg, params, slo=SLO(3.0, 150.0), max_slots=4,
                          max_len=48, obs=obs)
    trace = generate_trace("sharegpt", rate_req_s=200.0, duration_s=10.0,
                           seed=3, max_requests=6)
    rng = np.random.default_rng(3)
    for r in trace:
        r.prompt_len = max(4, min(r.prompt_len, 16))
        r.output_len = max(2, min(r.output_len, 8))
    fe = OnlineFrontend(server, VirtualClock(),
                        cycle_cost=estimator_cycle_cost)
    for r in trace:
        fe.submit(r, rng.integers(0, cfg.vocab_size, r.prompt_len,
                                  dtype=np.int32))
    m = fe.run()
    assert m.n_requests == len(trace)
    return server, trace, m


# -- histogram / percentile math ---------------------------------------

def test_histogram_buckets_and_cumulative():
    r = MetricsRegistry()
    h = r.histogram("t_seconds", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 1.5, 3.0, 9.0):
        h.observe(v)
    assert h.counts == [1, 2, 1, 1]          # last slot is +Inf
    assert h.cumulative() == [1, 3, 4, 5]
    assert h.count == 5
    assert h.sum == pytest.approx(15.5)
    assert h.mean == pytest.approx(3.1)


def test_histogram_quantile_interpolation():
    r = MetricsRegistry()
    h = r.histogram("t_seconds", buckets=(1.0, 2.0, 4.0))
    for _ in range(4):
        h.observe(1.5)                        # all land in (1, 2]
    # rank q*4 interpolated linearly inside the (1, 2] bucket
    assert h.quantile(0.5) == pytest.approx(1.5)
    assert h.quantile(1.0) == pytest.approx(2.0)
    h.observe(100.0)                          # +Inf bucket
    assert h.quantile(1.0) == 4.0             # clamps to last finite bound
    assert math.isnan(MetricsRegistry().histogram(
        "e_seconds", buckets=(1.0,)).quantile(0.5))


def test_histogram_rejects_duplicate_buckets():
    with pytest.raises(AssertionError):
        MetricsRegistry().histogram("bad_seconds", buckets=(1.0, 1.0))


def test_prometheus_render_and_snapshot():
    r = MetricsRegistry()
    c = r.counter("reqs_total", "requests", labels=("kind",))
    c.labels(kind="a").inc()
    c.labels(kind="a").inc(2)
    c.labels(kind="b").inc()
    r.gauge("occ", "occupancy").set(0.25)
    h = r.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    text = r.render()
    assert '# TYPE reqs_total counter' in text
    assert 'reqs_total{kind="a"} 3' in text
    assert 'reqs_total{kind="b"} 1' in text
    assert 'occ 0.25' in text
    # cumulative buckets ending in +Inf, plus _sum/_count
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="1"} 2' in text
    assert 'lat_seconds_bucket{le="+Inf"} 2' in text
    assert 'lat_seconds_count 2' in text
    snap = r.snapshot()
    assert snap['reqs_total{kind="a"}'] == 3
    assert snap["lat_seconds_count"] == 2
    assert snap["lat_seconds_sum"] == pytest.approx(0.55)
    assert r.value("reqs_total", kind="a") == 3
    assert r.value("reqs_total", kind="zzz") is None
    assert r.value("nope") is None


def test_registry_rejects_kind_or_label_redefinition():
    r = MetricsRegistry()
    r.counter("m_total", labels=("kind",))
    with pytest.raises(AssertionError):
        r.gauge("m_total")
    with pytest.raises(AssertionError):
        r.counter("m_total", labels=("other",))


def test_disabled_registry_is_noop():
    r = MetricsRegistry(enabled=False)
    c = r.counter("x_total")
    assert c is NULL_INSTRUMENT
    assert isinstance(c.labels(kind="a"), _NullInstrument)
    c.inc()
    r.gauge("g").set(5)
    r.histogram("h_seconds").observe(1.0)
    assert r.snapshot() == {}
    assert r.render() == ""
    # the NULL_OBS singleton: spans and traces append nothing
    NULL_OBS.spans.mark(0, "submit", 0.0)
    assert NULL_OBS.spans.all() == []
    assert len(NULL_OBS.trace) == 0


# -- ServingMetrics zero-finished sentinel ------------------------------

def test_serving_metrics_empty_sentinel():
    m = ServingMetrics.from_requests([], WORKLOAD_SLOS["sharegpt"])
    assert m.is_empty
    for f in dataclass_fields(ServingMetrics):
        v = getattr(m, f.name)
        assert v == 0 and not math.isnan(v), f.name
    assert "n=0" in m.row() and "NaN" not in m.row()
    # unfinished requests only -> same sentinel
    m2 = ServingMetrics.from_requests(
        [Request(rid=0, arrival=0.0, prompt_len=4, output_len=4)],
        WORKLOAD_SLOS["sharegpt"])
    assert m2.is_empty


# -- Chrome trace-event export ------------------------------------------

def test_chrome_trace_schema_valid(replayed):
    server, trace, _ = replayed
    doc = server.obs.chrome_trace()
    text = json.dumps(doc)                   # must be JSON-serializable
    doc = json.loads(text)
    evs = doc["traceEvents"]
    assert evs and doc["otherData"]["dropped_cycles"] == 0
    for e in evs:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(e), e
        assert e["ph"] in {"X", "C", "M", "b", "e", "n"}, e
        assert e["ts"] >= 0
    # VirtualClock timestamps are monotone under the exporter's sort
    ts = [e["ts"] for e in evs]
    assert ts == sorted(ts)
    cycles = [e for e in evs if e["ph"] == "X"]
    assert cycles
    for e in cycles:
        assert e["dur"] >= 0
        assert e["name"].startswith("cycle:")
        # estimator-clocked replay: every cycle carries both durations
        assert e["args"]["predicted_ms"] is not None
        assert e["args"]["actual_ms"] is not None
    # one async begin/end pair per finished request
    b = [e for e in evs if e["ph"] == "b"]
    e_ = [e for e in evs if e["ph"] == "e"]
    assert len(b) == len(e_) == len(trace)
    assert {e["id"] for e in b} == {str(r.rid) for r in trace}


def test_counters_and_spans_cover_the_run(replayed):
    server, trace, _ = replayed
    obs = server.obs
    assert obs.registry.value(
        "bullet_requests_submitted_total") == len(trace)
    assert obs.registry.value(
        "bullet_requests_finished_total") == len(trace)
    for r in trace:
        span = obs.spans.get(r.rid)
        assert span.count("submit") == 1
        assert span.count("first_token") == 1
        assert span.count("finish") == 1
        bd = span.breakdown()
        assert bd["ttft_s"] >= 0 and bd["queue_s"] >= 0
        assert bd["ttft_s"] == pytest.approx(r.ttft)


def test_metrics_snapshot_reconciles_with_engine_stats(replayed):
    server, trace, m = replayed
    obs = server.obs
    obs.sync_engine_stats(server)
    snap = obs.registry.snapshot()
    for f in dataclass_fields(server.stats):
        assert snap[f"bullet_engine_{f.name}_total"] == float(
            getattr(server.stats, f.name)), f.name
    assert snap['bullet_kv_pool_ops_total{op="free"}'] == \
        server.pool.ops.frees
    # cycle histograms saw every observed cycle
    n_cycles = sum(v for k, v in snap.items()
                   if k.startswith("bullet_cycle_seconds_count"))
    assert n_cycles == len(obs.trace)
    assert snap["bullet_kv_free_blocks"] == server.pool.free_blocks
    # the rendered exposition carries the same numbers
    text = obs.render_metrics()
    assert (f"bullet_engine_decode_iterations_total "
            f"{server.stats.decode_iterations}") in text


def test_span_invariants_across_preempt_resume(setup):
    """The preemption recipe from test_frontend, instrumented: the
    victim's span accumulates preempt/resume marks, keeps exactly one
    first_token, and its breakdown stays attributable."""
    cfg, params = setup
    obs = Observability()
    server = BulletServer(cfg, params, slo=SLO(3.0, 150.0), max_slots=2,
                          max_len=40, max_prefill_batch=1, obs=obs)
    server.pool = PagedKVPool(48, block_size=16)
    rng = np.random.default_rng(1)
    young = Request(rid=0, arrival=1.0, prompt_len=8, output_len=12)
    server.submit(young, rng.integers(0, cfg.vocab_size, 8))
    now = 1.0
    while young.phase != Phase.DECODE:
        server.step(now)
        now += 1e-3
    for _ in range(3):
        server.step(now)
        now += 1e-3
    old = Request(rid=1, arrival=0.0, prompt_len=30, output_len=4)
    server.submit(old, rng.integers(0, cfg.vocab_size, 30))
    while old.phase == Phase.QUEUED:
        server.step(now)
        now += 1e-3
    assert server.stats.preempted == 1
    while not server.idle:                   # drain on the same clock
        server.step(now)
        now += 1e-3
    server.pool.check_invariants()
    assert young.phase == Phase.FINISHED

    span = obs.spans.get(young.rid)
    assert span.count("submit") == 1
    assert span.count("finish") == 1
    assert span.count("preempt") == 1
    assert span.count("resume") == 1
    assert span.count("admit") == 1          # initial admission only
    # resumed prefill does not re-emit the first token
    assert span.count("first_token") == 1
    ts = [e.t for e in span.events]
    assert ts == sorted(ts)
    bd = span.breakdown()
    assert bd["preempts"] == bd["resumes"] == 1
    assert bd["queue_s"] >= 0 and bd["ttft_s"] >= 0
    assert bd["decode_s"] >= 0
    assert span.end >= span.start
    # pool op counters saw the eviction
    obs.sync_engine_stats(server)
    assert obs.registry.value("bullet_kv_pool_ops_total", op="preempt") \
        == 1


def test_cycle_events_describe_the_cycle(replayed):
    server, _, _ = replayed
    kinds = {ev.kind for ev in server.obs.trace}
    assert kinds <= {"serial", "fused", "chip"} and kinds
    for ev in server.obs.trace:
        assert ev.predicted_s > 0
        assert ev.actual_s is not None and ev.actual_s > 0
        assert 0.0 <= ev.kv_occupancy <= 1.0
        assert ev.kv_used_blocks <= ev.kv_total_blocks
        assert ev.reason != ""
        assert ev.decode_batch >= 0 and ev.prefill_tokens >= 0
    # scheduler rationale counters cover every decision-carrying cycle
    snap = server.obs.registry.snapshot()
    decided = sum(v for k, v in snap.items()
                  if k.startswith("bullet_scheduler_decisions_total"))
    assert decided > 0
